"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import sys
import time


MODULES = [
    "fidelity",          # Figs. 5-6
    "engine_fidelity",   # paged Engine vs simulator replay (calibration loop)
    "engine_chunked",    # chunked prefill: ITL stall + long-context scenarios
    "regression_fit",    # SIII-E1
    "batching_matrix",   # Figs. 10-12 + Table III
    "reasoning",         # Fig. 8
    "rag_placement",     # Fig. 9
    "kv_storage",        # Fig. 15
    "kv_paging",         # paged allocator: block x preemption x tier sweep
    "prefix_cache",      # radix cache: branches x reuse x capacity sweep
    "prefix_migration",  # cross-client migration: BW x reuse x scale-out
    "scaling_clients",   # Fig. 13
    "engine_disagg",     # real prefill/decode split: measured KV handoff
    "disaggregation",    # SII-B global/local + SIII-B2 transfer granularity
    "chunk_sweep",       # Fig. 6 chunk axis / Sarathi trade-off
    "spec_decode",       # SIII-E1 spec decode: engine + analytical + sim
    "kernel_bench",      # kernel rooflines
    "sim_throughput",    # simulator cost: decode fast-forward on vs off
    "fleet_scale",       # simulator cost: indexed routing at 10..1000 clients
    "autoscale",         # closed-loop autoscaler: diurnal goodput vs cost
]


def main() -> None:
    import importlib

    only = sys.argv[1:] or None
    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for line in mod.run():
                print(line, flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # surface but keep going
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
