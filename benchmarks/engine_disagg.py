"""Disaggregated engine handoff: measure the real prefill->decode KV-page
transfer and calibrate the simulator's link pricing from it.

Three arms per granularity (paper §III-B2: full vs layerwise KV transfer):

1. **DisaggEngine** (``repro.engine.workers``) — prefill worker(s) really
   prefill, finished KV pages really move (``jax.device_put`` across devices
   when the host has >= 2, host-staged otherwise), decode worker(s) really
   continue the stream. Every handoff is timed; ``transfer_stats()`` yields
   the wire bytes, the total and *exposed* transfer seconds (layerwise
   exposes only the slowest single layer — the rest overlaps pipelined
   compute), and the raw ``(bytes, seconds)`` samples.
2. **oracle Engine** — the single-engine run of the same schedule. Under
   greedy decoding the disaggregated streams must be **bit-identical** (the
   --check gate): worker pairing, handoff timing and per-role preemption
   may reorder WHEN tokens are computed, never WHAT they are.
3. **simulator** (``repro.core`` "disaggregated" strategy) — the same
   global/local x full/layerwise pricing, run twice: once on the catalog
   ``LinkSpec`` constants and once with the prefill->decode links
   re-priced via ``Network.override_link`` to the alpha-beta fit of THIS
   host's measured samples (``perfmodel.regression.fit_link_spec``). That
   closes the measure->calibrate->replay loop; ``benchmarks/disaggregation``
   picks the fitted constants up from the emitted JSON.

Emits ``BENCH_engine_disagg.json``. ``--smoke`` pins the small CI scenario;
with ``--check`` it exits non-zero when

* any disaggregated token stream differs from the single-engine oracle,
* a schedule did not complete, or no bytes moved over the handoff,
* layerwise exposed stall exceeds full-granularity stall beyond a CPU-noise
  tolerance (per-handoff mean; the payloads here are KB-scale so both are
  overhead-dominated — the gate bounds the ratio rather than asserting the
  asymptotic n_layers speedup), or
* the fitted link constants are not finite/positive.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

if __package__ in (None, ""):                      # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import row

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_engine_disagg.json")

BLOCK_TOKENS = 16
MAX_BATCH = 2
MAX_LEN = 96
SHARED_PREFIX = 32           # block-aligned shared system prompt (2 blocks)
OUT_TOKENS = 8
SMOKE_N = 4
FULL_N = 8
# layerwise-vs-full exposed-stall gate: ratio bound + absolute slack for
# overhead-dominated KB-scale CPU transfers (see module docstring)
EXPOSED_TOL_RATIO = 2.0
EXPOSED_TOL_ABS_S = 1e-3


def _schedule(n: int, seed: int, vocab: int):
    rng = np.random.default_rng(seed)
    sysp = rng.integers(0, vocab, SHARED_PREFIX)
    return [np.concatenate([sysp,
                            rng.integers(0, vocab, int(rng.integers(4, 12)))
                            ]).astype(np.int32) for _ in range(n)]


def _run_disagg(cfg, params, prompts, granularity: str, mode: str,
                n_prefill: int, n_decode: int) -> Dict:
    from repro.engine.workers import DisaggEngine

    eng = DisaggEngine(cfg, params, n_prefill=n_prefill, n_decode=n_decode,
                       mode=mode, granularity=granularity,
                       max_batch=MAX_BATCH, max_len=MAX_LEN,
                       block_tokens=BLOCK_TOKENS)
    handles = [eng.submit(p, max_new_tokens=OUT_TOKENS) for p in prompts]
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    for w in eng.prefill + eng.decode:
        w.store.check_invariants()
    ts = eng.transfer_stats()
    return {"handles": handles, "wall_s": wall, "transfer": ts,
            "completed": all(h.state == "done" for h in handles)}


def _run_oracle(cfg, params, prompts):
    from repro.engine.workers import oracle_engine

    eng = oracle_engine(cfg, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                        block_tokens=BLOCK_TOKENS)
    handles = [eng.submit(p, max_new_tokens=OUT_TOKENS) for p in prompts]
    eng.run()
    return handles


def _run_sim(gran: str, mode: str, n_req: int, link_spec=None) -> Dict:
    """Simulator arm: same disaggregation mode/granularity; with
    ``link_spec`` the prefill->decode links are re-priced to the measured
    fit before any traffic flows."""
    from repro.core import SystemSpec, build_system
    from repro.core.llm_scheduler import SchedulerLimits
    from repro.core.request import DECODE, PREFILL, Request, Stage

    spec = SystemSpec(model="gemma-2b", strategy="disaggregated",
                      n_prefill=1, n_decode=1, disaggregation=mode,
                      kv_transfer_granularity=gran, with_pre_post=False,
                      limits=SchedulerLimits(max_batch=MAX_BATCH,
                                             kv_block_tokens=BLOCK_TOKENS))
    coord = build_system(spec)
    if link_spec is not None:
        for name in ("rack", "nvlink"):
            coord.network.override_link(name, link_spec)
    reqs = [Request(arrival=0.0, input_tokens=SHARED_PREFIX + 8,
                    output_tokens=OUT_TOKENS, model="gemma-2b",
                    stages=[Stage(PREFILL), Stage(DECODE)])
            for _ in range(n_req)]
    coord.submit(reqs)
    m = coord.run()
    s = m.summary()
    return {"ttft_mean_s": s.get("ttft_mean"),
            "tpot_mean_s": s.get("tpot_mean"),
            "comm_bytes": m.comm_bytes}


def _scenario(n: int, mode: str, n_prefill: int, n_decode: int) -> Dict:
    from repro.configs import get_reduced_config
    from repro.models import transformer as tf
    from repro.perfmodel.regression import fit_link_spec
    import jax

    cfg = get_reduced_config("gemma_2b")
    params, _ = tf.init_model(cfg, jax.random.PRNGKey(7))
    prompts = _schedule(n, seed=11, vocab=cfg.vocab_size)
    oracle = _run_oracle(cfg, params, prompts)

    arms, samples = {}, []
    for gran in ("full", "layerwise"):
        r = _run_disagg(cfg, params, prompts, gran, mode,
                        n_prefill, n_decode)
        streams_equal = all(a.tokens == b.tokens
                            for a, b in zip(r["handles"], oracle))
        ts = r["transfer"]
        samples.extend(ts["samples"])
        arms[gran] = {
            "streams_equal": streams_equal,
            "completed": r["completed"],
            "wall_s": r["wall_s"],
            "handoffs": ts["handoffs"],
            "bytes": ts["bytes"],
            "pages": ts["pages"],
            "total_s": ts["total_s"],
            "exposed_s": ts["exposed_s"],
            "exposed_per_handoff_s": (ts["exposed_s"] / ts["handoffs"]
                                      if ts["handoffs"] else 0.0),
            "dedup_blocks": ts["dedup_blocks"],
            "cross_device": ts["cross_device"],
        }

    fitted = fit_link_spec(samples, name=f"measured_handoff_{mode}")
    sim = {}
    for gran in ("full", "layerwise"):
        sim[gran] = {
            "default": _run_sim(gran, mode, n),
            "measured": _run_sim(gran, mode, n, link_spec=fitted),
        }
    return {
        "n_requests": n, "mode": mode,
        "n_prefill": n_prefill, "n_decode": n_decode,
        "arms": arms,
        "fitted_link": {"name": fitted.name,
                        "bandwidth_bytes_per_s": fitted.bandwidth,
                        "latency_s": fitted.latency,
                        "n_samples": len(samples)},
        "sim": sim,
    }


def run(smoke: bool = False) -> List[str]:
    out, results = [], []
    plans = [(SMOKE_N, "local", 1, 1)]
    if not smoke:
        plans.append((FULL_N, "global", 2, 2))
    for n, mode, n_p, n_d in plans:
        r = _scenario(n, mode, n_p, n_d)
        results.append(r)
        for gran, a in r["arms"].items():
            out.append(row(
                f"engine_disagg_{mode}_{gran}{'_smoke' if smoke else ''}",
                a["wall_s"] * 1e6,
                f"streams_equal={a['streams_equal']} "
                f"handoffs={a['handoffs']} bytes={a['bytes']} "
                f"exposed={a['exposed_per_handoff_s']*1e6:.0f}us/handoff "
                f"dedup_blocks={a['dedup_blocks']}"))
        fl = r["fitted_link"]
        out.append(row(
            f"engine_disagg_{mode}_fit", 0.0,
            f"bw={fl['bandwidth_bytes_per_s']:.3g}B/s "
            f"alpha={fl['latency_s']*1e6:.1f}us "
            f"n_samples={fl['n_samples']}"))
    with open(JSON_PATH, "w") as f:
        json.dump({"smoke": smoke, "block_tokens": BLOCK_TOKENS,
                   "max_batch": MAX_BATCH, "max_len": MAX_LEN,
                   "results": results}, f, indent=2, default=float)
    out.append(f"# wrote {JSON_PATH}")
    return out


def check(path: str) -> int:
    """CI gate (see module docstring)."""
    with open(path) as f:
        data = json.load(f)
    rc = 0
    for r in data["results"]:
        tag = f"mode={r['mode']} n={r['n_requests']}"
        for gran, a in r["arms"].items():
            if not a["streams_equal"]:
                print(f"CHECK FAIL: {tag} {gran} token streams diverge "
                      "from the single-engine oracle", file=sys.stderr)
                rc = 1
            if not a["completed"]:
                print(f"CHECK FAIL: {tag} {gran} schedule did not complete",
                      file=sys.stderr)
                rc = 1
            if a["bytes"] <= 0 or a["handoffs"] <= 0:
                print(f"CHECK FAIL: {tag} {gran} no KV bytes moved over "
                      "the handoff", file=sys.stderr)
                rc = 1
        full = r["arms"]["full"]["exposed_per_handoff_s"]
        layer = r["arms"]["layerwise"]["exposed_per_handoff_s"]
        if layer > full * EXPOSED_TOL_RATIO + EXPOSED_TOL_ABS_S:
            print(f"CHECK FAIL: {tag} layerwise exposed stall "
                  f"{layer*1e6:.0f}us exceeds full {full*1e6:.0f}us beyond "
                  "tolerance", file=sys.stderr)
            rc = 1
        fl = r["fitted_link"]
        if not (np.isfinite(fl["bandwidth_bytes_per_s"])
                and fl["bandwidth_bytes_per_s"] > 0
                and np.isfinite(fl["latency_s"]) and fl["latency_s"] >= 0):
            print(f"CHECK FAIL: {tag} fitted link constants not "
                  f"finite/positive: {fl}", file=sys.stderr)
            rc = 1
        for gran in ("full", "layerwise"):
            if r["sim"][gran]["measured"]["ttft_mean_s"] is None:
                print(f"CHECK FAIL: {tag} {gran} simulator arm with "
                      "measured constants produced no TTFT", file=sys.stderr)
                rc = 1
    if rc == 0:
        print("CHECK OK: disaggregated streams identical to the oracle; "
              "real bytes moved; layerwise stall within tolerance; "
              "measured link constants fitted and replayed")
    return rc


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)
    if "--check" in sys.argv:
        raise SystemExit(check(JSON_PATH))
