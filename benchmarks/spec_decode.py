"""Speculative decoding trade-off (paper §III-E1 optimization list): TPOT of
plain decode vs draft-and-verify for varying acceptance rates and draft
lengths, Llama-3-70B target + 2B-class draft on 2xH100 TP2."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import row
from repro.configs import get_config
from repro.core.system import _guard_model_2b
from repro.perfmodel import analytical as ana
from repro.perfmodel.hardware import ClusterSpec, H100


def run() -> List[str]:
    out = []
    target = get_config("llama3_70b")
    draft = _guard_model_2b()
    cluster = ClusterSpec(H100, n_chips=2, tp=2)
    batch, ctx = 16, 2048
    base = ana.decode_step_time(target, cluster, batch, ctx)
    out.append(row("specdec_baseline", base.time * 1e6,
                   f"tpot={base.time*1e3:.1f}ms tokens_per_step=1.0"))
    for k in (2, 4, 8):
        for alpha in (0.6, 0.8, 0.9):
            t0 = time.perf_counter()
            cost, accepted = ana.speculative_decode_step(
                target, draft, cluster, batch, ctx, k=k, alpha=alpha)
            eff_tpot = cost.time / accepted
            us = (time.perf_counter() - t0) * 1e6
            speedup = base.time / eff_tpot
            out.append(row(
                f"specdec_k{k}_a{alpha}", us,
                f"eff_tpot={eff_tpot*1e3:.1f}ms accepted={accepted:.2f} "
                f"speedup={speedup:.2f}x"))
    return out
