"""Speculative decoding end-to-end: measured draft-and-verify in the real
paged Engine, calibrated back into the analytical model and the simulator.

Three arms close the loop (paper §III-E1's optimization list):

* **engine** (measured, reduced model on CPU): the paged ``Engine`` with
  ``EngineConfig(draft_cfg=..., spec_k=...)`` against the plain decode
  engine on an identical schedule. Three draft qualities bracket the
  mechanism — ``cold`` (an independent guard-2b-class draft: acceptance
  ~0, the floor), ``noisy`` (the target's own weights perturbed by small
  Gaussian noise: partial agreement, the realistic middle), ``perfect``
  (the target as its own draft: acceptance 1, the ceiling). Every arm must
  stream BIT-IDENTICAL tokens to plain decode; per arm we record wall
  time, target passes, committed tokens per verify step, and the measured
  per-position acceptance distribution (``Engine.spec_stats()``).
* **analytical** (predicted): ``perfmodel.speculative_decode_step`` sweeps
  k x alpha for the full-size pair (Llama-3-70B target + guard-2b draft on
  2xH100 TP2), AND re-prices each engine arm with its MEASURED acceptance
  distribution — ``expected_accepted_tokens(k, measured)`` is the
  predicted tokens/step the gate compares against the engine's measured
  value.
* **simulator** (replayed): the discrete-event scheduler with
  ``SchedulerLimits(spec_k=..., spec_acceptance=<measured distribution>)``
  vs the plain scheduler on the same workload — the SPEC_DECODE stage
  must improve decode-bound TPOT when fed the perfect arm's measured
  acceptance.

Emits ``BENCH_spec_decode.json``. With ``--check`` it exits non-zero when
any spec arm's streams diverge from plain decode, the perfect arm fails to
commit >1 token per verify step (the reason the feature exists), any arm's
predicted-vs-measured tokens/step error exceeds ``CAL_TOL``, or the
simulator's spec TPOT fails to beat its plain TPOT.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

if __package__ in (None, ""):                      # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import row

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_spec_decode.json")

BLOCK_TOKENS = 16
MAX_BATCH = 3
MAX_LEN = 96
PROMPT_LENS = (9, 14, 9, 20)      # few distinct lengths: few prefill jits
MAX_NEW = 24                      # decode-bound: decode dominates prefill
NOISE_SCALE = 0.1                 # 'noisy' draft: target weights + N(0, s^2)
                                  # (picked for partial acceptance ~0.2 on
                                  # the reduced model; 0.06 still accepts
                                  # everything, 0.15 accepts nothing)
SMOKE_KS = (4,)
FULL_KS = (2, 4)
CAL_TOL = 0.35                    # |predicted - measured| / predicted gate


# ---------------------------------------------------------------------------
# engine arm (measured)
# ---------------------------------------------------------------------------

def _prompts(vocab: int, seed: int = 5):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, n).astype(np.int32) for n in PROMPT_LENS]


def _run_engine(cfg, params, prompts, *, spec_k=0, draft_cfg=None,
                draft_params=None):
    from repro.engine.runner import Engine, EngineConfig

    conf = EngineConfig(draft_cfg=draft_cfg, spec_k=spec_k)
    eng = Engine(cfg, params=params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                 block_tokens=BLOCK_TOKENS, config=conf,
                 draft_params=draft_params)
    for p in prompts:
        eng.submit(p, max_new_tokens=MAX_NEW)
    t0 = time.perf_counter()
    fin = eng.run()
    wall = time.perf_counter() - t0
    eng.store.check_invariants()
    return eng, {r.rid: list(r.tokens) for r in fin}, wall


def _noisy_params(params, scale: float):
    import jax
    import jax.numpy as jnp

    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(23), len(leaves))
    noisy = [l + scale * jax.random.normal(k, l.shape, l.dtype)
             if jnp.issubdtype(l.dtype, jnp.floating) else l
             for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, noisy)


def _engine_scenario(ks) -> Dict:
    import jax

    from repro.configs import get_reduced_config
    from repro.models import transformer as tf
    from repro.perfmodel.analytical import expected_accepted_tokens

    cfg = get_reduced_config("gemma_2b")
    draft_cfg = get_reduced_config("guard_2b")
    params, _ = tf.init_model(cfg, jax.random.PRNGKey(7))
    draft_params, _ = tf.init_model(draft_cfg, jax.random.PRNGKey(8))
    prompts = _prompts(cfg.vocab_size)

    _run_engine(cfg, params, prompts)              # warm the plain shapes
    _, base_streams, base_wall = _run_engine(cfg, params, prompts)
    base_steps = sum(len(t) for t in base_streams.values())

    variants = [
        ("cold", draft_cfg, draft_params),
        ("noisy", cfg, _noisy_params(params, NOISE_SCALE)),
        ("perfect", cfg, params),
    ]
    arms = []
    for name, dcfg, dparams in variants:
        for k in ks:
            eng, streams, _ = _run_engine(cfg, params, prompts, spec_k=k,
                                          draft_cfg=dcfg,
                                          draft_params=dparams)   # warm jits
            eng, streams, wall = _run_engine(cfg, params, prompts, spec_k=k,
                                             draft_cfg=dcfg,
                                             draft_params=dparams)
            st = eng.spec_stats()
            cond = st["conditional_acceptance_per_position"]
            pred = expected_accepted_tokens(k, cond)
            prop = sum(st["proposed_per_position"])
            acc = sum(st["accepted_per_position"])
            arms.append({
                "draft": name, "spec_k": k,
                "streams_equal": streams == base_streams,
                "tokens_per_step": st["tokens_per_step"],
                "row_steps": st["row_steps"],
                "iterations": st["iterations"],
                "emitted": st["emitted"],
                "acceptance_per_position": st["acceptance_per_position"],
                "conditional_acceptance": cond,
                "fitted_alpha": acc / prop if prop else 0.0,
                "predicted_tokens_per_step": pred,
                "calibration_error": (abs(pred - st["tokens_per_step"])
                                      / max(pred, 1e-9)),
                "wall_s": wall,
            })
    return {
        "prompt_lens": list(PROMPT_LENS), "max_new": MAX_NEW,
        "plain_wall_s": base_wall, "plain_target_passes": base_steps,
        "arms": arms,
    }


# ---------------------------------------------------------------------------
# analytical arm (predicted, full-size pair)
# ---------------------------------------------------------------------------

def _analytical_scenario(engine: Dict) -> Dict:
    from repro.configs import get_config
    from repro.perfmodel import analytical as ana
    from repro.perfmodel.hardware import ClusterSpec, H100

    target = get_config("llama3_70b")
    draft = get_config("guard_2b")
    cluster = ClusterSpec(H100, n_chips=2, tp=2)
    batch, ctx = 16, 2048
    base = ana.decode_step_time(target, cluster, batch, ctx)
    sweep = []
    for k in (2, 4, 8):
        for alpha in (0.6, 0.8, 0.9):
            cost, accepted = ana.speculative_decode_step(
                target, draft, cluster, batch, ctx, k=k, alpha=alpha)
            sweep.append({
                "k": k, "alpha": alpha, "accepted": accepted,
                "eff_tpot_s": cost.time / accepted,
                "speedup": base.time / (cost.time / accepted),
            })
    # re-price with each engine arm's MEASURED acceptance distribution:
    # the closed loop between real execution and the analytical model
    measured = []
    for a in engine["arms"]:
        cost, accepted = ana.speculative_decode_step(
            target, draft, cluster, batch, ctx, k=a["spec_k"],
            alpha=a["conditional_acceptance"])
        measured.append({
            "draft": a["draft"], "k": a["spec_k"], "accepted": accepted,
            "speedup": base.time / (cost.time / accepted),
        })
    return {"plain_tpot_s": base.time, "sweep": sweep,
            "measured_acceptance": measured}


# ---------------------------------------------------------------------------
# simulator arm (SPEC_DECODE stage)
# ---------------------------------------------------------------------------

def _simulator_scenario(engine: Dict) -> Dict:
    from repro.core import SystemSpec, WorkloadConfig, build_system, generate
    from repro.core.llm_scheduler import SchedulerLimits
    from repro.core.workload import AZURE_CODE

    best = max((a for a in engine["arms"] if a["draft"] == "perfect"),
               key=lambda a: a["spec_k"])

    def tpot(limits):
        spec = SystemSpec(n_llm_clients=2, strategy="continuous",
                          limits=limits, with_pre_post=False)
        coord = build_system(spec)
        wl = WorkloadConfig(trace=AZURE_CODE, rate=2.0, n_requests=30,
                            postprocess=False, seed=41)
        coord.submit(generate(wl))
        return coord.run().summary()["tpot_p50"]

    plain = tpot(SchedulerLimits())
    spec = tpot(SchedulerLimits(
        spec_k=best["spec_k"],
        spec_acceptance=tuple(best["conditional_acceptance"])))
    return {
        "spec_k": best["spec_k"],
        "acceptance": best["conditional_acceptance"],
        "plain_tpot_p50_s": plain,
        "spec_tpot_p50_s": spec,
        "tpot_improvement": plain / max(spec, 1e-12),
    }


# ---------------------------------------------------------------------------

def run(smoke: bool = False) -> List[str]:
    ks = SMOKE_KS if smoke else FULL_KS
    engine = _engine_scenario(ks)
    analytical = _analytical_scenario(engine)
    simulator = _simulator_scenario(engine)
    out = []
    sfx = "_smoke" if smoke else ""
    for a in engine["arms"]:
        out.append(row(
            f"specdec_engine_{a['draft']}_k{a['spec_k']}{sfx}",
            a["wall_s"] * 1e6,
            f"streams_equal={a['streams_equal']} "
            f"tok_per_step={a['tokens_per_step']:.2f} "
            f"pred={a['predicted_tokens_per_step']:.2f} "
            f"alpha={a['fitted_alpha']:.2f} "
            f"cal_err={a['calibration_error']:.2f}"))
    for s in analytical["sweep"]:
        out.append(row(
            f"specdec_ana_k{s['k']}_a{s['alpha']}{sfx}",
            s["eff_tpot_s"] * 1e6,
            f"accepted={s['accepted']:.2f} speedup={s['speedup']:.2f}x"))
    out.append(row(
        f"specdec_sim{sfx}", simulator["spec_tpot_p50_s"] * 1e6,
        f"tpot_improvement={simulator['tpot_improvement']:.2f}x "
        f"k={simulator['spec_k']}"))
    with open(JSON_PATH, "w") as f:
        json.dump({"smoke": smoke, "cal_tol": CAL_TOL, "engine": engine,
                   "analytical": analytical, "simulator": simulator},
                  f, indent=2, default=float)
    out.append(f"# wrote {JSON_PATH}")
    return out


def check(path: str) -> int:
    """CI gate (see module docstring)."""
    with open(path) as f:
        data = json.load(f)
    rc = 0
    tol = data["cal_tol"]
    perfect_ok = False
    for a in data["engine"]["arms"]:
        tag = f"{a['draft']}/k={a['spec_k']}"
        if not a["streams_equal"]:
            print(f"CHECK FAIL: {tag}: speculative streams diverge from "
                  "plain decode", file=sys.stderr)
            rc = 1
        if a["calibration_error"] > tol:
            print(f"CHECK FAIL: {tag}: predicted {a['predicted_tokens_per_step']:.2f} "
                  f"vs measured {a['tokens_per_step']:.2f} tokens/step — "
                  f"error {a['calibration_error']:.2f} > {tol}",
                  file=sys.stderr)
            rc = 1
        if a["draft"] == "perfect" and a["tokens_per_step"] > 1.0:
            perfect_ok = True
    if not perfect_ok:
        print("CHECK FAIL: perfect-draft arm never committed more than one "
              "token per verify step — speculation is not speculating",
              file=sys.stderr)
        rc = 1
    if data["simulator"]["tpot_improvement"] <= 1.0:
        print("CHECK FAIL: simulator SPEC_DECODE stage does not improve "
              f"TPOT (x{data['simulator']['tpot_improvement']:.2f})",
              file=sys.stderr)
        rc = 1
    if rc == 0:
        best = max(data["engine"]["arms"],
                   key=lambda a: a["tokens_per_step"])
        print("CHECK OK: spec streams bit-identical to plain decode; "
              f"best arm {best['draft']}/k={best['spec_k']} commits "
              f"{best['tokens_per_step']:.2f} tokens/step "
              f"(predicted {best['predicted_tokens_per_step']:.2f}); "
              "simulator TPOT improves "
              f"x{data['simulator']['tpot_improvement']:.2f}")
    return rc


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)
    if "--check" in sys.argv:
        raise SystemExit(check(JSON_PATH))
