"""Per-kernel micro-benchmarks: wall time of the executable path on this host
(jnp reference — the Pallas kernels target TPU and are validated in interpret
mode) + derived FLOPs/bytes for the roofline discussion."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)


def run() -> List[str]:
    out = []
    # flash attention (prefill) sweep
    for b, s, nh, kvh, d in [(1, 1024, 8, 2, 128), (2, 2048, 16, 4, 128)]:
        q = jax.random.normal(KEY, (b, s, nh, d), jnp.float32)
        k = jax.random.normal(KEY, (b, s, kvh, d), jnp.float32)
        v = jax.random.normal(KEY, (b, s, kvh, d), jnp.float32)
        fn = jax.jit(lambda q, k, v: ref.chunked_flash_attention(
            q, k, v, causal=True, block_q=512, block_k=512))
        us = timeit(lambda: fn(q, k, v).block_until_ready(), n=3)
        fl = 4.0 * b * nh * s * s / 2 * d
        out.append(row(f"flash_b{b}_s{s}_h{nh}", us,
                       f"gflops={fl/1e9:.1f} eff_gflops_s={fl/us/1e3:.1f}"))
    # decode attention sweep
    for b, S, nh, kvh, d in [(8, 4096, 32, 8, 128), (32, 2048, 16, 2, 128)]:
        q = jax.random.normal(KEY, (b, 1, nh, d), jnp.float32)
        k = jax.random.normal(KEY, (b, S, kvh, d), jnp.float32)
        v = jax.random.normal(KEY, (b, S, kvh, d), jnp.float32)
        lens = jnp.full((b,), S - 1, jnp.int32)
        fn = jax.jit(lambda q, k, v, l: ref.decode_attention(q, k, v, l))
        us = timeit(lambda: fn(q, k, v, lens).block_until_ready(), n=3)
        by = 2.0 * b * S * kvh * d * 4
        out.append(row(f"decode_b{b}_S{S}", us,
                       f"gbytes={by/1e9:.2f} eff_gb_s={by/us/1e3:.1f}"))
    # pq scan
    for N, M in [(100_000, 16), (500_000, 8)]:
        codes = jax.random.randint(KEY, (N, M), 0, 256)
        lut = jax.random.normal(KEY, (M, 256), jnp.float32)
        fn = jax.jit(ref.pq_scan)
        us = timeit(lambda: fn(codes, lut).block_until_ready(), n=3)
        out.append(row(f"pqscan_N{N}_M{M}", us,
                       f"mcodes_s={N*M/us:.1f}"))
    return out
