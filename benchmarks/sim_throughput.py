"""Simulator-throughput benchmark: what a fleet-scale sweep point *costs us*.

Every other benchmark measures the modeled system; this one measures the
simulator itself — wall-clock seconds, simulated engine steps per wall
second, and heap events popped — across fleet sizes on a decode-heavy
scenario (small prompts, ~1k-token outputs, batch 64), with the decode
fast-forward engine on vs off. It also re-verifies the engine's core
contract on every scenario it touches: ``MetricsCollector.summary()`` must
be identical in both modes.

Emits ``BENCH_sim_throughput.json`` next to this file. ``--smoke`` runs the
single pinned CI scenario; with ``--check`` it exits non-zero when the
fast-forward event count regresses more than 2x over the pinned budget, when
the two modes disagree on any summary, or when the smoke speedup collapses.
"""
from __future__ import annotations

import json
import math
import os
import sys
import time
from typing import Dict, List, Tuple

if __package__ in (None, ""):                      # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import row
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.llm_scheduler import SchedulerLimits
from repro.core.metrics import simulator_stats
from repro.core.workload import synthetic_trace

# decode-heavy fleet scenario: prompts are small, outputs long, arrivals
# front-loaded (saturating rate, so every client's 64-slot batch fills almost
# immediately) — the regime every reasoning / batching / KV-tier sweep axis
# spends most of its simulated time in
FLEETS = (1, 2, 4)
REQS_PER_CLIENT = 64
OUT_TOKENS = 1000
RATE_PER_CLIENT = 32.0
REPEATS = 3                     # wall-clock = best of N (first run warms caches)
SMOKE_FLEET = 2
SMOKE_REQS_PER_CLIENT = 24
SMOKE_OUT_TOKENS = 300

# pinned CI budget: heap events popped by the *smoke* scenario with
# fast-forward on (measured 136; headroom for deterministic drift when
# scheduling internals change legitimately). --check fails beyond 2x.
SMOKE_EVENTS_PINNED = 200
# wall-clock floors are advisory only under --check: events popped is the
# deterministic regression signal; timing on shared CI runners is not.
SMOKE_MIN_SPEEDUP = 2.0
TARGET_SPEEDUP = 5.0            # full decode-heavy scenario target


def _workload(n_clients: int, reqs_per_client: int, out_tokens: int,
              seed: int = 9) -> WorkloadConfig:
    trace = synthetic_trace(input_mean=128, input_std=0.3,
                            output_mean=out_tokens, output_std=0.15,
                            name="decode-heavy")
    return WorkloadConfig(trace=trace, rate=RATE_PER_CLIENT * n_clients,
                          n_requests=reqs_per_client * n_clients,
                          process="poisson", postprocess=False, seed=seed)


def _run_mode(fast_forward: bool, n_clients: int, reqs_per_client: int,
              out_tokens: int) -> Tuple[Dict, Dict, float]:
    spec = SystemSpec(n_llm_clients=n_clients, strategy="continuous",
                      limits=SchedulerLimits(max_batch=64,
                                             fast_forward=fast_forward),
                      with_pre_post=False)
    coord = build_system(spec)
    coord.submit(generate(_workload(n_clients, reqs_per_client, out_tokens)))
    t0 = time.perf_counter()
    metrics = coord.run()
    wall = time.perf_counter() - t0
    return metrics.summary(), simulator_stats(coord), wall


def _summaries_equal(a: Dict, b: Dict) -> bool:
    if set(a) != set(b):
        return False
    for k in a:
        x, y = a[k], b[k]
        if x == y:
            continue
        if isinstance(x, float) and isinstance(y, float) \
                and math.isnan(x) and math.isnan(y):
            continue
        return False
    return True


def _bench_fleet(n_clients: int, reqs_per_client: int,
                 out_tokens: int) -> Dict:
    walls_on, walls_off = [], []
    for _ in range(REPEATS):
        s_on, st_on, w = _run_mode(True, n_clients, reqs_per_client,
                                   out_tokens)
        walls_on.append(w)
    for _ in range(REPEATS):
        s_off, st_off, w = _run_mode(False, n_clients, reqs_per_client,
                                     out_tokens)
        walls_off.append(w)
    wall_on, wall_off = min(walls_on), min(walls_off)
    return {
        "fleet": n_clients,
        "n_requests": reqs_per_client * n_clients,
        "out_tokens": out_tokens,
        "wall_s_on": wall_on,
        "wall_s_off": wall_off,
        "speedup": wall_off / max(wall_on, 1e-9),
        "events_popped_on": st_on["events_popped"],
        "events_popped_off": st_off["events_popped"],
        "micro_steps": st_on["micro_steps"],
        "micro_steps_off": st_off["micro_steps"],
        "macro_windows": st_on["macro_windows"],
        "steps_per_s_on": st_on["micro_steps"] / max(wall_on, 1e-9),
        "steps_per_s_off": st_off["micro_steps"] / max(wall_off, 1e-9),
        "summary_match": _summaries_equal(s_on, s_off),
        "throughput_tok_s": s_on["throughput_tok_s"],
    }


def _write_json(results: List[Dict], smoke: bool) -> str:
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_sim_throughput.json")
    with open(path, "w") as f:
        json.dump({"scenario": "decode-heavy fleet (continuous, batch 64)",
                   "smoke": smoke,
                   "pinned_smoke_events": SMOKE_EVENTS_PINNED,
                   "results": results}, f, indent=1)
    return path


def run(smoke: bool = False) -> List[str]:
    out = []
    if smoke:
        grid = [(SMOKE_FLEET, SMOKE_REQS_PER_CLIENT, SMOKE_OUT_TOKENS)]
    else:
        grid = [(f, REQS_PER_CLIENT, OUT_TOKENS) for f in FLEETS]
    results = []
    for fleet, rpc, out_tok in grid:
        t0 = time.perf_counter()
        r = _bench_fleet(fleet, rpc, out_tok)
        results.append(r)
        us = (time.perf_counter() - t0) * 1e6
        out.append(row(
            f"simtp_fleet{fleet}{'_smoke' if smoke else ''}", us,
            f"speedup={r['speedup']:.1f}x "
            f"events={r['events_popped_on']}/{r['events_popped_off']} "
            f"steps/s={r['steps_per_s_on']:.0f} "
            f"match={r['summary_match']}"))
    path = _write_json(results, smoke)
    out.append(row("simtp_json", 0.0, f"wrote {path} ({len(results)} points)"))
    return out


def check(results_path: str) -> int:
    """CI gate over the smoke point: events-popped budget (2x pin) and
    summary equivalence fail hard — both are deterministic. The wall-clock
    floor is advisory (shared CI runners make timing assertions flaky)."""
    with open(results_path) as f:
        data = json.load(f)
    errors = []
    smoke = bool(data.get("smoke"))
    for r in data["results"]:
        if not r["summary_match"]:
            errors.append(f"fleet {r['fleet']}: summaries diverge between "
                          f"fast-forward on/off")
        if smoke and r["events_popped_on"] > 2 * SMOKE_EVENTS_PINNED:
            errors.append(f"fleet {r['fleet']}: events popped "
                          f"{r['events_popped_on']} > 2x pinned budget "
                          f"{SMOKE_EVENTS_PINNED}")
        if smoke and r["speedup"] < SMOKE_MIN_SPEEDUP:
            print(f"CHECK WARNING: fleet {r['fleet']}: speedup "
                  f"{r['speedup']:.2f}x below advisory floor "
                  f"{SMOKE_MIN_SPEEDUP}x", file=sys.stderr)
    for e in errors:
        print(f"CHECK FAILED: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)
    if "--check" in sys.argv:
        json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "BENCH_sim_throughput.json")
        raise SystemExit(check(json_path))
