"""Chunked prefill in the real paged Engine: the ITL-stall experiment.

The whole-prompt engine admits a long prompt by running its entire prefill
between two decode iterations — every running request's inter-token latency
(ITL) absorbs the full prompt length as one stall (the head-of-line problem
Sarathi/DeepSpeed-FastGen chunked prefill exists to fix, and the trade-off
behind the paper's chunk-size axis). The chunked engine admits the same
prompt for free and advances it ``chunk_size`` tokens per MIXED iteration
alongside the running decodes, so the worst per-iteration stall is bounded
by the chunk, not the prompt.

Scenarios (all greedy, reduced model on CPU, engines warmed so jit
compilation never lands in a measured iteration):

* **stall** — two steady decoders reach steady state, then a long prompt
  arrives mid-stream. Arms: whole-prefill (``chunk_size=0``) vs a grid of
  chunk sizes, all fed the identical schedule. Per arm: the steady
  decoders' ITL distribution (median / p99 / max), the long prompt's TTFT
  (the other side of the knob), and token streams, which must be
  bit-identical to the dense ``SlotEngine`` oracle. A simulator replay of
  the same schedule under ``strategy="chunked"`` sits alongside as the
  calibration arm (predicted-vs-measured ratios, as in engine_fidelity).
* **long_context** — a prompt ~3x ``max_len``. The whole-prefill engine
  must REJECT it at submit (eager validation); the chunked engine
  (``max_context=384``) must complete it with a token stream bit-identical
  to a dense oracle sized to ``max_context``.

Emits ``BENCH_engine_chunked.json``. With ``--check`` it exits non-zero
when any arm's stream diverges from its oracle, the long-context prompt is
not completed (chunked) or not rejected (whole), the smallest-chunk arm's
ITL p99 exceeds ``STALL_MULT`` x its own steady median (the bounded-stall
claim), or no chunked arm improves ITL p99 over the whole-prefill arm (the
reason the feature exists).
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

if __package__ in (None, ""):                      # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import row

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_engine_chunked.json")

BLOCK_TOKENS = 16
MAX_BATCH = 3
MAX_LEN = 96
STEADY_LEN = 8               # two steady decoders, same length (one compile)
STEADY_NEW = 20
STEADY_STEPS = 6             # decode iterations before the long prompt lands
LONG_LEN = 80                # fits the whole-prefill engine (< max_len - 2)
LONG_NEW = 4
SMOKE_CHUNKS = (8, 32)
FULL_CHUNKS = (4, 8, 16, 32, 64)
# bounded-stall gate, applied to the smallest chunk arm: its ITL p99 may not
# exceed this multiple of its own steady-state median. The whole-prefill arm
# runs LONG_LEN prompt tokens inside one inter-token gap; the smallest chunk
# arm runs MAX_BATCH*min(chunk) padded tokens — ~8x median leaves headroom
# for CPU jitter while still refuting an unbounded stall.
STALL_MULT = 8.0
CTX_LEN = 300                # long-context scenario: prompt >> max_len
CTX_MAX = 384
CTX_CHUNK = 32
CTX_NEW = 6


def _prompts(vocab: int, seed: int = 5):
    rng = np.random.default_rng(seed)
    steady = [rng.integers(1, vocab, STEADY_LEN).astype(np.int32)
              for _ in range(MAX_BATCH - 1)]
    long_p = rng.integers(1, vocab, LONG_LEN).astype(np.int32)
    return steady, long_p


def _drive(eng, steady, long_p):
    """Steady decoders first, long prompt mid-stream — the schedule every
    arm (and the oracle) replays. Mirrors Engine.run()'s admit/step loop."""
    hs = [eng.submit(p, max_new_tokens=STEADY_NEW) for p in steady]
    step = eng._step_mixed if eng.chunk_size else eng._step_decode
    eng._admit()
    for _ in range(STEADY_STEPS):
        step()
    hl = eng.submit(long_p, max_new_tokens=LONG_NEW)
    guard = 0
    while (any(r is not None for r in eng.active) or eng.waiting) \
            and guard < 10_000:
        eng._admit()
        step()
        guard += 1
    return hs, hl


def _arm(cfg, params, steady, long_p, chunk: int) -> Dict:
    from repro.engine.runner import Engine, EngineConfig

    eng = Engine(cfg, params=params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                 block_tokens=BLOCK_TOKENS,
                 config=EngineConfig(chunk_size=chunk))
    _drive(eng, steady, long_p)                    # warm-up: jit every shape
    eng2 = eng                                     # same instance, drained
    t0 = time.perf_counter()
    hs, hl = _drive(eng2, steady, long_p)
    wall = time.perf_counter() - t0
    eng2.store.check_invariants()
    itls = [g for h in hs for g in h.itl]
    return {
        "chunk_size": chunk,
        "completed": all(h.state == "done" for h in hs + [hl]),
        "streams": {**{i: list(h.tokens) for i, h in enumerate(hs)},
                    "long": list(hl.tokens)},
        "itl_median_s": float(np.median(itls)),
        "itl_p99_s": float(np.percentile(itls, 99)),
        "itl_max_s": float(np.max(itls)),
        "stall_ratio": float(np.percentile(itls, 99) / np.median(itls)),
        "long_ttft_s": hl.ttft,
        "wall_s": wall,
        "steps": eng2.steps,
    }


def _oracle_streams(cfg, params, steady, long_p, max_len=MAX_LEN) -> Dict:
    from repro.engine.runner import SlotEngine

    slot = SlotEngine(cfg, params=params, max_batch=MAX_BATCH,
                      max_len=max_len)
    hs = [slot.submit(p, max_new_tokens=STEADY_NEW) for p in steady]
    hl = slot.submit(long_p, max_new_tokens=LONG_NEW)
    slot.run()
    return {**{i: list(h.tokens) for i, h in enumerate(hs)},
            "long": list(hl.tokens)}


def _simulate_chunked(steady, long_p, chunk: int) -> Dict:
    """Calibration arm: the same schedule through the discrete-event
    simulator's chunked strategy (predicted TTFT/TPOT for the full model on
    H100 — comparable to the measured arm only through a per-metric ratio,
    exactly as in engine_fidelity)."""
    from repro.core import SystemSpec, build_system
    from repro.core.llm_scheduler import SchedulerLimits
    from repro.core.request import LLM, Request, Stage

    spec = SystemSpec(model="gemma-2b", n_llm_clients=1, strategy="chunked",
                      with_pre_post=False,
                      limits=SchedulerLimits(max_batch=MAX_BATCH,
                                             kv_block_tokens=BLOCK_TOKENS,
                                             chunk_size=chunk))
    coord = build_system(spec)
    reqs = [Request(arrival=0.0, input_tokens=len(p),
                    output_tokens=STEADY_NEW, model="gemma-2b",
                    stages=[Stage(LLM)]) for p in steady]
    reqs.append(Request(arrival=0.0, input_tokens=len(long_p),
                        output_tokens=LONG_NEW, model="gemma-2b",
                        stages=[Stage(LLM)]))
    coord.submit(reqs)
    s = coord.run().summary()
    return {k: v for k, v in s.items()
            if k.startswith(("ttft", "tpot", "kv_"))}


def _stall_scenario(chunks) -> Dict:
    import jax

    from repro.configs import get_reduced_config
    from repro.models import transformer as tf

    cfg = get_reduced_config("gemma_2b")
    params, _ = tf.init_model(cfg, jax.random.PRNGKey(7))
    steady, long_p = _prompts(cfg.vocab_size)
    oracle = _oracle_streams(cfg, params, steady, long_p)
    arms = [_arm(cfg, params, steady, long_p, c) for c in (0, *chunks)]
    for a in arms:
        a["streams_equal"] = a.pop("streams") == oracle
    whole, chunked = arms[0], arms[1:]
    best = min(chunked, key=lambda a: a["itl_p99_s"])
    sim = _simulate_chunked(steady, long_p, min(chunks))
    meas_ttft = best["long_ttft_s"]
    pred_ttft = sim.get("ttft_mean")
    return {
        "arms": arms,
        "whole_itl_p99_s": whole["itl_p99_s"],
        "best_chunked_itl_p99_s": best["itl_p99_s"],
        "best_chunk_size": best["chunk_size"],
        "p99_improvement": whole["itl_p99_s"] / max(best["itl_p99_s"], 1e-9),
        "sim_chunked": sim,
        "ttft_calibration_ratio": (meas_ttft / pred_ttft
                                   if pred_ttft and meas_ttft else None),
    }


def _long_context_scenario() -> Dict:
    import jax

    from repro.configs import get_reduced_config
    from repro.engine.runner import Engine, EngineConfig
    from repro.models import transformer as tf

    cfg = get_reduced_config("gemma_2b")
    params, _ = tf.init_model(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(13)
    prompt = rng.integers(1, cfg.vocab_size, CTX_LEN).astype(np.int32)

    whole = Engine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                   block_tokens=BLOCK_TOKENS)
    try:
        whole.submit(prompt, max_new_tokens=CTX_NEW)
        rejected = False
    except ValueError:
        rejected = True

    from repro.engine.runner import SlotEngine
    slot = SlotEngine(cfg, params=params, max_batch=2, max_len=CTX_MAX)
    ho = slot.submit(prompt, max_new_tokens=CTX_NEW)
    slot.run()

    eng = Engine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                 block_tokens=BLOCK_TOKENS,
                 config=EngineConfig(chunk_size=CTX_CHUNK, max_context=CTX_MAX))
    h = eng.submit(prompt, max_new_tokens=CTX_NEW)
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    eng.store.check_invariants()
    return {
        "prompt_tokens": CTX_LEN,
        "max_len": MAX_LEN,
        "max_context": CTX_MAX,
        "whole_rejected": rejected,
        "chunked_completed": h.state == "done",
        "streams_equal": list(h.tokens) == list(ho.tokens),
        "chunked_wall_s": wall,
        "chunked_steps": eng.steps,
    }


def run(smoke: bool = False) -> List[str]:
    chunks = SMOKE_CHUNKS if smoke else FULL_CHUNKS
    stall = _stall_scenario(chunks)
    ctx = _long_context_scenario()
    out = []
    for a in stall["arms"]:
        tag = a["chunk_size"] or "whole"
        out.append(row(
            f"engine_chunk_{tag}{'_smoke' if smoke else ''}",
            a["wall_s"] * 1e6,
            f"streams_equal={a['streams_equal']} "
            f"itl_p99={a['itl_p99_s']*1e3:.1f}ms "
            f"itl_med={a['itl_median_s']*1e3:.1f}ms "
            f"stall_ratio={a['stall_ratio']:.1f} "
            f"long_ttft={a['long_ttft_s']*1e3:.0f}ms"))
    out.append(row(
        f"engine_chunk_longctx{'_smoke' if smoke else ''}",
        ctx["chunked_wall_s"] * 1e6,
        f"completed={ctx['chunked_completed']} "
        f"streams_equal={ctx['streams_equal']} "
        f"whole_rejected={ctx['whole_rejected']} "
        f"p={ctx['prompt_tokens']}>max_len={ctx['max_len']}"))
    with open(JSON_PATH, "w") as f:
        json.dump({"smoke": smoke, "block_tokens": BLOCK_TOKENS,
                   "max_batch": MAX_BATCH, "max_len": MAX_LEN,
                   "stall_mult": STALL_MULT, "stall": stall,
                   "long_context": ctx}, f, indent=2, default=float)
    out.append(f"# wrote {JSON_PATH}")
    return out


def check(path: str) -> int:
    """CI gate (see module docstring)."""
    with open(path) as f:
        data = json.load(f)
    rc = 0
    stall, ctx = data["stall"], data["long_context"]
    for a in stall["arms"]:
        tag = a["chunk_size"] or "whole"
        if not a["streams_equal"]:
            print(f"CHECK FAIL: arm {tag}: token streams diverge from the "
                  "dense oracle", file=sys.stderr)
            rc = 1
        if not a["completed"]:
            print(f"CHECK FAIL: arm {tag}: schedule did not complete",
                  file=sys.stderr)
            rc = 1
    chunked = [a for a in stall["arms"] if a["chunk_size"]]
    smallest = min(chunked, key=lambda a: a["chunk_size"])
    if smallest["itl_p99_s"] > data["stall_mult"] * smallest["itl_median_s"]:
        print(f"CHECK FAIL: chunk {smallest['chunk_size']}: ITL p99 "
              f"{smallest['itl_p99_s']*1e3:.1f}ms exceeds "
              f"{data['stall_mult']}x steady median "
              f"{smallest['itl_median_s']*1e3:.1f}ms — the stall is not "
              "bounded by the chunk", file=sys.stderr)
        rc = 1
    if stall["best_chunked_itl_p99_s"] >= stall["whole_itl_p99_s"]:
        print("CHECK FAIL: no chunked arm improves ITL p99 over the "
              f"whole-prefill arm ({stall['best_chunked_itl_p99_s']*1e3:.1f}"
              f"ms vs {stall['whole_itl_p99_s']*1e3:.1f}ms)", file=sys.stderr)
        rc = 1
    if not ctx["whole_rejected"]:
        print("CHECK FAIL: whole-prefill engine accepted a prompt beyond "
              "max_len (eager validation broken)", file=sys.stderr)
        rc = 1
    if not (ctx["chunked_completed"] and ctx["streams_equal"]):
        print("CHECK FAIL: long-context prompt not completed bit-identically "
              "by the chunked engine", file=sys.stderr)
        rc = 1
    if rc == 0:
        print("CHECK OK: chunked streams bit-identical; long-prompt ITL "
              "stall bounded by the chunk and improved over whole-prefill; "
              f"{ctx['prompt_tokens']}-token prompt served past "
              f"max_len={ctx['max_len']}")
    return rc


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)
    if "--check" in sys.argv:
        raise SystemExit(check(JSON_PATH))
