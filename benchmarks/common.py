"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple


def timeit(fn: Callable, n: int = 5, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
